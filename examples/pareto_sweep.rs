//! Pareto sweep (Figures 2 & 5): train the resnet20 scheme grid (or reuse
//! cached results from earlier harness runs) and print accuracy vs
//! effectual parameters with the Pareto front marked.
//!
//! Run: `make artifacts && cargo run --release --example pareto_sweep -- --steps 150`

use plum::cli::args::Args;
use plum::config::RunConfig;
use plum::experiments::{tables, train_and_measure};
use plum::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = RunConfig::resolve(&args)?;
    let rt = Runtime::cpu()?;

    // the headline grid: four schemes on resnet20 plus the width-reduced
    // binary (table 7's equal-effectual comparator)
    for name in [
        "resnet20_fp",
        "resnet20_ternary",
        "resnet20_binary",
        "resnet20_sb",
        "resnet20w07_b",
    ] {
        println!("-- {name}");
        let row = train_and_measure(&cfg, &rt, name, args.has("fresh"), true)?;
        println!(
            "   acc {:.3}  effectual {:.0}k  density {:.2}  ({:.0}s)",
            row.eval_acc,
            row.effectual as f64 / 1e3,
            row.density,
            row.wall_secs
        );
    }
    tables::pareto(&cfg)?;
    Ok(())
}
