"""AOT emitter: lower every configured model to HLO text + manifest.

For each :class:`common.ModelConfig` this writes into ``--out-dir``:

* ``<name>.train.hlo.txt`` — one optimizer step (fwd + bwd + Adam + BN
  update), inputs/outputs in manifest order.
* ``<name>.infer.hlo.txt`` — eval-mode forward; for scheme 'sb' the
  quantized convs route through the L1 Pallas signed-binary GEMM.
* ``<name>.manifest.json`` — exact positional input/output signature
  (group, name, shape, dtype), config echo, conv-layer geometry for the
  rust repetition engine, parameter counts.
* ``<name>.params.bin`` — initial params ++ bn ++ consts as raw little-
  endian f32 in manifest order (Adam m/v start at zero rust-side).

Plus once per build: ``index.json`` (experiment-id -> artifact names) and
``golden_quant.json`` (cross-language quantizer fixtures for rust tests).

HLO **text** (never ``.serialize()``) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common, model
from .kernels import ref
from .kernels import signed_binary as sbk

F32 = "f32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig_entries(group: str, d: dict) -> list:
    return [
        {
            "group": group,
            "name": k,
            "shape": list(d[k].shape),
            "dtype": F32,
        }
        for k in sorted(d.keys())
    ]


def _scalar(name: str, group: str = "hyper") -> dict:
    return {"group": group, "name": name, "shape": [], "dtype": F32}


def build_config_set(which: str):
    """The artifact grid. Keyed so each unique config is emitted once;
    index.json maps experiment ids to the config names they consume."""
    C = common.ModelConfig
    cfgs: dict = {}

    def add(cfg):
        cfgs[cfg.name] = cfg
        return cfg.name

    index: dict = {}

    # --- Table 1 / Figure 5 / E2E: cifar resnets, 4 schemes -----------------
    t1_depths = [20] if which == "default" else [20, 32, 44, 56, 110]
    index["table1"] = []
    for d in t1_depths:
        row = {}
        for sch in ("fp", "binary", "ternary", "sb"):
            row[sch] = add(C(name=f"resnet{d}_{sch}", depth=d, scheme=sch))
        index["table1"].append({"depth": d, **row})
    index["e2e"] = "resnet20_sb"

    # --- resnet8 ablation grid (Tables 2-5, 8) ------------------------------
    base = dict(arch="cifar_resnet", depth=8, image_size=16, batch_size=32,
                scheme="sb")
    index["table2"] = []
    for p in (0.0, 0.25, 0.5, 0.75, 1.0):
        nm = add(C(name=f"r8sb_p{int(p*100):03d}", p_pos=p, **base))
        index["table2"].append({"p_pos": p, "cfg": nm})
    index["table3"] = {
        "enabled": "r8sb_p050",
        "disabled": add(C(name="r8sb_noede", use_ede=False, **base)),
    }
    index["table4"] = {
        "ct_c": "r8sb_p050",
        "ct_c2": add(C(name="r8sb_g2", regions_per_filter=2, **base)),
    }
    index["table5"] = {
        "d005": "r8sb_p050",
        "d001": add(C(name="r8sb_d001", delta_frac=0.01, **base)),
    }
    index["table8a"] = {}
    for bs in (16, 64, 128):
        b2 = dict(base)
        b2["batch_size"] = bs
        index["table8a"][str(bs)] = add(C(name=f"r8sb_bs{bs}", **b2))
    index["table8a"]["32"] = "r8sb_p050"
    index["table8b"] = {"prelu": "r8sb_p050"}
    for act in ("relu", "tanh", "lrelu"):
        index["table8b"][act] = add(C(name=f"r8sb_{act}", act=act, **base))

    # --- Table 6: SB vs FP on additional datasets ---------------------------
    index["table6"] = []
    for arch, ds, ncls, px in (
        ("alexnet_small", "svhn-like", 10, 32),
        ("vgg_small", "cifar-like", 10, 32),
        ("resnet18", "cifar100-like", 100, 32),
        ("resnet18", "tinyimagenet-like", 20, 48),
    ):
        wm = 0.25 if arch == "resnet18" else 0.5
        pair = {}
        for sch in ("sb", "fp"):
            nm = add(C(name=f"{arch}_{ds.split('-')[0]}_{sch}", arch=arch,
                       width_mult=wm, num_classes=ncls, image_size=px,
                       scheme=sch))
            pair[sch] = nm
        index["table6"].append({"arch": arch, "dataset": ds, **pair})

    # --- Table 7: SB vs B at comparable effectual params --------------------
    index["table7"] = {
        "depth": {
            "sb_d32": add(C(name="resnet32_sb7", depth=32, scheme="sb")),
            "b_d32": add(C(name="resnet32_b7", depth=32, scheme="binary")),
            "b_d20": "resnet20_binary",
        },
        "width": {
            "sb_w10": "resnet20_sb",
            "b_w10": "resnet20_binary",
            "b_w07": add(C(name="resnet20w07_b", depth=20, scheme="binary",
                           width_mult=0.7)),
        },
    }

    # --- Tables 10-12: imagenet-proxy ablations (resnet18 @48px) -----------
    pbase = dict(arch="resnet18", width_mult=0.25, num_classes=20,
                 image_size=48, scheme="sb", batch_size=32)
    index["table10"] = {
        "p100": add(C(name="r18p_p100", p_pos=1.0, **pbase)),
        "p025": add(C(name="r18p_p025", p_pos=0.25, **pbase)),
        "p050": add(C(name="r18p_p050", p_pos=0.5, **pbase)),
    }
    index["table11"] = {
        "enabled": "r18p_p050",
        "disabled": add(C(name="r18p_noede", use_ede=False, **pbase)),
    }
    index["table12"] = {
        "d005": "r18p_p050",
        "d001": add(C(name="r18p_d001", delta_frac=0.01, **pbase)),
    }

    # --- Table 9: latent-weight standardization strategies ------------------
    index["table9"] = {
        "none": "r8sb_p050",
        "local": add(C(name="r8sb_stdlocal", standardize="local", **base)),
        "global": add(C(name="r8sb_stdglobal", standardize="global", **base)),
    }

    # --- serving / figure 7 workload ---------------------------------------
    index["serving"] = add(C(name="resnet18sb", arch="resnet18",
                             num_classes=10, image_size=64, scheme="sb",
                             batch_size=8))
    return cfgs, index


def emit_model(cfg: common.ModelConfig, out_dir: str,
               train: bool = True) -> dict:
    t0 = time.time()
    params, bn, consts, qnames, conv_log = model.init(cfg, seed=0)
    bs = cfg.batch_size
    x_spec = jax.ShapeDtypeStruct(
        (bs, cfg.in_channels, cfg.image_size, cfg.image_size), jnp.float32
    )
    y_spec = jax.ShapeDtypeStruct((bs,), jnp.int32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    spec_of = lambda d: {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in d.items()}
    p_s, bn_s, c_s = spec_of(params), spec_of(bn), spec_of(consts)

    files = {}
    if train:
        step_fn = model.make_train_step(cfg, qnames)
        lowered = jax.jit(step_fn, keep_unused=True).lower(
            p_s, bn_s, c_s, p_s, p_s, x_spec, y_spec, sc, sc, sc
        )
        text = to_hlo_text(lowered)
        files["train"] = f"{cfg.name}.train.hlo.txt"
        with open(os.path.join(out_dir, files["train"]), "w") as f:
            f.write(text)

    infer_fn = model.make_infer(cfg, use_pallas=(cfg.scheme == "sb"))
    lowered_i = jax.jit(infer_fn, keep_unused=True).lower(p_s, bn_s, c_s, x_spec)
    text_i = to_hlo_text(lowered_i)
    files["infer"] = f"{cfg.name}.infer.hlo.txt"
    with open(os.path.join(out_dir, files["infer"]), "w") as f:
        f.write(text_i)

    # initial state blob: params ++ bn ++ consts, manifest order
    blob = b"".join(
        np.asarray(d[k], np.float32).tobytes()
        for d in (params, bn, consts)
        for k in sorted(d.keys())
    )
    files["params"] = f"{cfg.name}.params.bin"
    with open(os.path.join(out_dir, files["params"]), "wb") as f:
        f.write(blob)

    total, qtotal, eff = model.param_counts(cfg, params, consts, qnames)
    train_inputs = (
        _sig_entries("params", params)
        + _sig_entries("bn", bn)
        + _sig_entries("consts", consts)
        + _sig_entries("opt_m", params)
        + _sig_entries("opt_v", params)
        + [
            {"group": "input", "name": "x",
             "shape": list(x_spec.shape), "dtype": F32},
            {"group": "input", "name": "y",
             "shape": list(y_spec.shape), "dtype": "i32"},
            _scalar("lr"), _scalar("step"), _scalar("progress"),
        ]
    )
    train_outputs = (
        [_scalar("loss", "metric"), _scalar("acc", "metric")]
        + _sig_entries("params", params)
        + _sig_entries("bn", bn)
        + _sig_entries("opt_m", params)
        + _sig_entries("opt_v", params)
    )
    infer_inputs = (
        _sig_entries("params", params)
        + _sig_entries("bn", bn)
        + _sig_entries("consts", consts)
        + [{"group": "input", "name": "x",
            "shape": list(x_spec.shape), "dtype": F32}]
    )
    manifest = {
        "name": cfg.name,
        "config": cfg.to_json_dict(),
        "files": files,
        "has_train": train,
        "train_inputs": train_inputs if train else [],
        "train_outputs": train_outputs if train else [],
        "infer_inputs": infer_inputs,
        "infer_outputs": [
            {"group": "output", "name": "logits",
             "shape": [bs, cfg.num_classes], "dtype": F32}
        ],
        "quantized_weights": qnames,
        "conv_layers": conv_log,
        "param_count": total,
        "quantized_param_count": qtotal,
        "effectual_params_init": eff,
    }
    with open(os.path.join(out_dir, f"{cfg.name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {cfg.name}: {time.time()-t0:.1f}s "
          f"(params={total}, eff_init={eff})", flush=True)
    return manifest


def emit_kernel_artifact(out_dir: str):
    """Standalone L1 sb_matmul artifact for the rust runtime micro-bench."""
    m, k, n = 256, 1152, 128
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    u = jax.ShapeDtypeStruct((k, n), jnp.float32)
    b = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(lambda a, u, b: sbk.sb_matmul(a, u, b)).lower(a, u, b)
    with open(os.path.join(out_dir, "sb_matmul.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    with open(os.path.join(out_dir, "sb_matmul.manifest.json"), "w") as f:
        json.dump({
            "name": "sb_matmul",
            "inputs": [
                {"name": "a", "shape": [m, k], "dtype": F32},
                {"name": "u", "shape": [k, n], "dtype": F32},
                {"name": "beta", "shape": [n], "dtype": F32},
            ],
            "outputs": [{"name": "o", "shape": [m, n], "dtype": F32}],
        }, f, indent=1)
    print("  sb_matmul kernel artifact", flush=True)


def emit_golden(out_dir: str):
    """Cross-language quantizer fixtures consumed by rust unit tests."""
    rng = np.random.RandomState(7)
    cases = []
    for scheme in ("binary", "ternary", "sb"):
        for shape in ((4, 3, 3, 3), (6, 8, 1, 1)):
            w = rng.randn(*shape).astype(np.float32)
            wj = jnp.asarray(w)
            beta = ref.default_beta(shape[0], 0.5)
            if scheme == "binary":
                wq = ref.binary_quantize_ref(wj)
            elif scheme == "ternary":
                wq = ref.ternary_quantize_ref(wj, 0.05)
            else:
                wq = ref.signed_binary_quantize_ref(wj, beta, 0.05)
            cases.append({
                "scheme": scheme,
                "shape": list(shape),
                "delta_frac": 0.05,
                "w": [float(v) for v in w.reshape(-1)],
                "beta": [float(v) for v in np.asarray(beta)],
                "wq": [float(v) for v in np.asarray(wq).reshape(-1)],
            })
    with open(os.path.join(out_dir, "golden_quant.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print("  golden_quant fixtures", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default="default", choices=["default", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated config names to (re)emit")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfgs, index = build_config_set(args.set)
    only = set(args.only.split(",")) if args.only else None
    print(f"emitting {len(cfgs)} configs to {args.out_dir}", flush=True)
    for name, cfg in cfgs.items():
        if only and name not in only:
            continue
        emit_model(cfg, args.out_dir, train=True)
    emit_kernel_artifact(args.out_dir)
    emit_golden(args.out_dir)
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print("index.json written", flush=True)


if __name__ == "__main__":
    main()
