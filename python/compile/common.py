"""Shared model/artifact configuration for the PLUM compile stack.

A :class:`ModelConfig` fully determines one AOT artifact pair
(`<name>.train.hlo.txt` + `<name>.infer.hlo.txt` + manifest + init params):
architecture, quantization scheme and its hyper-parameters, activation,
input geometry and batch size are all baked into the lowered HLO, exactly
like the paper trains one network per configuration.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

SCHEMES = ("fp", "binary", "ternary", "sb")
ACTS = ("relu", "prelu", "tanh", "lrelu")
ARCHS = ("cifar_resnet", "resnet18", "vgg_small", "alexnet_small")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One trainable/deployable network configuration."""

    name: str
    arch: str = "cifar_resnet"
    depth: int = 20                 # cifar_resnet: 6n+2
    width_mult: float = 1.0
    num_classes: int = 10
    image_size: int = 32
    in_channels: int = 3
    batch_size: int = 32
    scheme: str = "sb"              # fp | binary | ternary | sb
    delta_frac: float = 0.05        # Delta = delta_frac * max|W| (paper)
    p_pos: float = 0.5              # fraction of {0,+1} regions (Table 2)
    regions_per_filter: int = 1     # G: C_t = C / G (Table 4)
    use_ede: bool = True            # adapted EDE in backward (Table 3)
    act: str = "prelu"              # non-linearity (Table 8b)
    ede_t_min: float = 0.1
    ede_t_max: float = 10.0
    # latent-weight standardization before quantization (Table 9):
    # "none" | "local" (per signed-binary region) | "global" (per layer)
    standardize: str = "none"

    def __post_init__(self):
        assert self.scheme in SCHEMES, self.scheme
        assert self.act in ACTS, self.act
        assert self.arch in ARCHS, self.arch
        assert self.standardize in ("none", "local", "global"), self.standardize
        if self.arch == "cifar_resnet":
            assert (self.depth - 2) % 6 == 0, f"depth {self.depth} != 6n+2"

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def cifar_stage_widths(width_mult: float) -> List[int]:
    """ResNet (CIFAR) stage widths, optionally width-scaled (Table 7b)."""
    return [max(4, int(round(w * width_mult))) for w in (16, 32, 64)]


def resnet18_stage_widths(width_mult: float) -> List[int]:
    return [max(8, int(round(w * width_mult))) for w in (64, 128, 256, 512)]


def vgg_small_plan(width_mult: float) -> List[Tuple[str, int]]:
    """VGG** (Cai et al. 2017 derivative): conv pairs + pools."""
    w = lambda c: max(8, int(round(c * width_mult)))
    return [
        ("conv", w(128)), ("conv", w(128)), ("pool", 0),
        ("conv", w(256)), ("conv", w(256)), ("pool", 0),
        ("conv", w(512)), ("conv", w(512)), ("pool", 0),
    ]


def alexnet_small_plan(width_mult: float) -> List[Tuple[str, int]]:
    """AlexNet* (DoReFa svhn-digit derivative): small conv trunk."""
    w = lambda c: max(8, int(round(c * width_mult)))
    return [
        ("conv", w(48)), ("pool", 0),
        ("conv", w(64)), ("conv", w(64)), ("pool", 0),
        ("conv", w(128)), ("conv", w(128)), ("pool", 0),
    ]
