"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy / lax ops only. pytest asserts allclose
between kernel and oracle across shape/dtype sweeps (hypothesis-style).

Weight layout convention is OIHW: ``w[K, C, R, S]`` with K output filters,
C input channels, RxS spatial kernel. Activations are NCHW.

Quantization semantics follow the paper:

* binary   — BWN-style: ``sign(w) * alpha`` with per-filter
  ``alpha = mean(|w|)`` (Rastegari et al., 2016).
* ternary  — TWN-style threshold: ``Delta = delta_frac * max(|w|)`` per
  filter; values above +Delta -> +alpha, below -Delta -> -alpha, else 0,
  with ``alpha = mean(|w|) over effectual elements`` (Li et al., 2016;
  Zhu et al., 2016 for the Delta rule the paper adopts).
* signed-binary (PLUM) — per *region* (default: per filter, ``C_t = C``)
  one of two sparse one-bit value sets: ``{0, +alpha}`` when the region's
  sign factor ``beta = +1`` and ``{0, -alpha}`` when ``beta = -1``
  (paper eq. (1)-(3)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Quantizers (forward semantics only — backward/STE lives in compile.quant)
# ---------------------------------------------------------------------------


def _per_filter(w: jnp.ndarray, fn) -> jnp.ndarray:
    """Apply ``fn`` over each filter (leading axis), returns [K, 1, 1, 1]."""
    k = w.shape[0]
    flat = w.reshape(k, -1)
    return fn(flat).reshape(k, 1, 1, 1)


def binary_quantize_ref(w: jnp.ndarray) -> jnp.ndarray:
    """BWN binary quantization: sign(w) * mean(|w|) per filter."""
    alpha = _per_filter(w, lambda f: jnp.mean(jnp.abs(f), axis=1))
    # sign(0) := +1 so every weight stays effectual (binary is dense).
    s = jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)
    return s * alpha


def ternary_delta_ref(w: jnp.ndarray, delta_frac: float = 0.05) -> jnp.ndarray:
    """Per-filter threshold Delta = delta_frac * max(|w|) (Zhu et al.)."""
    return _per_filter(w, lambda f: delta_frac * jnp.max(jnp.abs(f), axis=1))


def ternary_quantize_ref(w: jnp.ndarray, delta_frac: float = 0.05) -> jnp.ndarray:
    """TWN ternary quantization with the paper's Delta rule."""
    delta = ternary_delta_ref(w, delta_frac)
    mask = (jnp.abs(w) > delta).astype(w.dtype)
    denom = jnp.maximum(_per_filter(mask, lambda f: jnp.sum(f, axis=1)), 1.0)
    alpha = _per_filter((jnp.abs(w) * mask), lambda f: jnp.sum(f, axis=1)) / denom
    return jnp.where(w > delta, alpha, jnp.where(w < -delta, -alpha, 0.0)).astype(
        w.dtype
    )


def sb_region_reshape(w: jnp.ndarray, regions_per_filter: int) -> jnp.ndarray:
    """[K,C,R,S] -> [K*G, C/G, R, S]: split C into G contiguous regions.

    This is the paper's intra-filter region ``R x S x C_t`` with
    ``C_t = C / G`` (Table 4 uses G in {1, 2}). G=1 is inter-filter
    signed binary (``C_t = C``), the PLUM default.
    """
    k, c, r, s = w.shape
    assert c % regions_per_filter == 0, (c, regions_per_filter)
    ct = c // regions_per_filter
    return w.reshape(k * regions_per_filter, ct, r, s)


def sb_region_unshape(
    wq: jnp.ndarray, k: int, c: int, regions_per_filter: int
) -> jnp.ndarray:
    """Inverse of :func:`sb_region_reshape`."""
    g = regions_per_filter
    _, ct, r, s = wq.shape
    assert ct * g == c
    return wq.reshape(k, c, r, s)


def signed_binary_quantize_ref(
    w: jnp.ndarray,
    beta: jnp.ndarray,
    delta_frac: float = 0.05,
    regions_per_filter: int = 1,
) -> jnp.ndarray:
    """PLUM signed-binary quantization (paper eq. 3).

    Args:
      w:    latent full-precision weights [K, C, R, S].
      beta: per-region sign factors in {+1.0, -1.0}, shape
            [K * regions_per_filter].
      delta_frac: Delta = delta_frac * max(|w_region|).
      regions_per_filter: G regions along C (C_t = C / G).

    Returns quantized weights, same shape as ``w``; each region holds values
    in {0, +alpha} or {0, -alpha} according to its beta.
    """
    k, c, r, s = w.shape
    wr = sb_region_reshape(w, regions_per_filter)
    b = beta.reshape(-1, 1, 1, 1).astype(w.dtype)
    delta = _per_filter(wr, lambda f: delta_frac * jnp.max(jnp.abs(f), axis=1))
    pos_eff = (wr >= delta) & (b >= 0)
    neg_eff = (wr <= -delta) & (b < 0)
    eff = (pos_eff | neg_eff).astype(w.dtype)
    denom = jnp.maximum(_per_filter(eff, lambda f: jnp.sum(f, axis=1)), 1.0)
    alpha = _per_filter(jnp.abs(wr) * eff, lambda f: jnp.sum(f, axis=1)) / denom
    wq = jnp.where(pos_eff, alpha, jnp.where(neg_eff, -alpha, 0.0)).astype(w.dtype)
    return sb_region_unshape(wq, k, c, regions_per_filter)


def default_beta(num_regions: int, p_pos: float = 0.5) -> jnp.ndarray:
    """Deterministic region->sign assignment, first ``p_pos`` fraction +1.

    The paper fixes the assignment randomly before training and never
    changes it; a fixed prefix split is an equivalent static assignment
    (interleaving is irrelevant because regions never interact inside the
    quantizer) and keeps the artifact deterministic.
    """
    n_pos = int(round(num_regions * p_pos))
    return jnp.concatenate(
        [
            jnp.ones((n_pos,), jnp.float32),
            -jnp.ones((num_regions - n_pos,), jnp.float32),
        ]
    )


# ---------------------------------------------------------------------------
# EDE (Error Decay Estimator) — backward-pass oracle (paper §3.2.3)
# ---------------------------------------------------------------------------


def ede_t_k(progress, t_min: float = 0.1, t_max: float = 10.0):
    """t = Tmin * 10^(progress * log10(Tmax/Tmin)), k = max(1/t, 1)."""
    t = t_min * jnp.power(10.0, progress * jnp.log10(t_max / t_min))
    k = jnp.maximum(1.0 / t, 1.0)
    return t, k


def ede_gprime_ref(
    w: jnp.ndarray,
    beta: jnp.ndarray,
    delta: jnp.ndarray,
    t,
    k,
    regions_per_filter: int = 1,
) -> jnp.ndarray:
    """g'(x) = k t (1 - tanh^2(t (x -+ Delta))), centred at the region's
    own threshold: +Delta for {0,1} regions, -Delta for {0,-1} regions."""
    kk, c, r, s = w.shape
    wr = sb_region_reshape(w, regions_per_filter)
    b = beta.reshape(-1, 1, 1, 1).astype(w.dtype)
    centre = jnp.where(b >= 0, delta, -delta)
    g = k * t * (1.0 - jnp.tanh(t * (wr - centre)) ** 2)
    return sb_region_unshape(g, kk, c, regions_per_filter)


# ---------------------------------------------------------------------------
# Conv / GEMM oracles
# ---------------------------------------------------------------------------


def conv2d_ref(
    x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, padding: int = 1
) -> jnp.ndarray:
    """NCHW x OIHW convolution."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def sb_conv2d_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    beta: jnp.ndarray,
    delta_frac: float = 0.05,
    stride: int = 1,
    padding: int = 1,
    regions_per_filter: int = 1,
) -> jnp.ndarray:
    """Quantize-then-convolve oracle for the signed-binary conv block."""
    wq = signed_binary_quantize_ref(w, beta, delta_frac, regions_per_filter)
    return conv2d_ref(x, wq, stride, padding)


def sb_matmul_ref(a: jnp.ndarray, u: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the signed-binary GEMM hot-spot.

    ``a [M, K] @ (u [K, N] * beta [N])`` where ``u`` is the {0, alpha}
    magnitude bitmap and ``beta`` the per-column (per-filter) sign. The
    kernel computes ``(a @ u) * beta`` — the matmul runs on the
    repetition-maximal bitmap, the sign is a scalar epilogue.
    """
    return (a @ u) * beta[None, :]


def im2col_ref(x: jnp.ndarray, r: int, s: int, stride: int, padding: int) -> jnp.ndarray:
    """NCHW -> patch matrix [N*OH*OW, C*R*S] matching tensor::im2col in rust."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    oh = (h + 2 * padding - r) // stride + 1
    ow = (w + 2 * padding - s) // stride + 1
    cols = []
    for i in range(r):
        for j in range(s):
            patch = xp[:, :, i : i + stride * oh : stride, j : j + stride * ow : stride]
            cols.append(patch.reshape(n, c, oh * ow))
    # [R*S, N, C, OH*OW] -> [N, OH*OW, C, R*S] -> [N*OH*OW, C*R*S]
    stacked = jnp.stack(cols, axis=0)
    out = stacked.transpose(1, 3, 2, 0).reshape(n * oh * ow, c * r * s)
    return out
