"""L1 Pallas kernels for PLUM signed-binary inference/training hot-spots.

Kernels are authored for a TPU-like memory hierarchy and validated on CPU
with ``interpret=True`` (real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot run). See DESIGN.md §Hardware-Adaptation for the
paper->TPU mapping; the short version:

* PLUM's CPU engine tiles the dot product so one processing step sees a
  single signed-binary quantization function. The Pallas analogue: the
  GEMM grid is tiled (bm, bn, bk) so each ``u``-block (the {0, alpha}
  magnitude bitmap) belongs to filters whose sign factor is constant over
  the tile column; the sign is applied as a scalar epilogue *after* the
  MXU contraction, so the inner matmul only ever sees the
  repetition-maximal bitmap.
* VMEM budget per grid step (f32): bm*bk + bk*bn + bm*bn floats. The
  default (128, 128, 128) uses 192 KiB — comfortably inside the ~16 MiB
  VMEM of a TPUv4 core, leaving room for double-buffered HBM streaming.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. Multiples of the 128x128 MXU systolic array on real TPUs;
# tests shrink them to exercise multi-step grids on tiny shapes.
#
# §Perf (L1 iteration 1): the original (128, 128, 128) tiling had
# arithmetic intensity 32 FLOP/byte — HBM-bound on any recent TPU
# (roofline knee ~ 240 for TPUv4 f32). (512, 256, 128) keeps full MXU
# utilization and only 6% of VMEM while raising intensity to 85
# FLOP/byte; bn stays modest because serving-model filter counts top out
# at 512 and a wider bn would burn the gain on N-padding. See
# kernels/analysis.py and EXPERIMENTS.md §Perf.
DEFAULT_BM = 512
DEFAULT_BN = 256
DEFAULT_BK = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Quantization kernels (elementwise over filter-major blocks)
# ---------------------------------------------------------------------------


def _sb_quantize_kernel(w_ref, beta_ref, delta_ref, alpha_ref, o_ref):
    """One grid step quantizes a [bk_filters, elems] block of latent weights.

    beta / delta / alpha are per-filter scalars broadcast along the element
    axis; the block never mixes the two quantization functions on a single
    filter row — the kernel-level embodiment of "a single processing step
    sees one signed-binary quantization function".
    """
    w = w_ref[...]
    beta = beta_ref[...]
    delta = delta_ref[...]
    alpha = alpha_ref[...]
    pos = jnp.logical_and(w >= delta, beta >= 0)
    neg = jnp.logical_and(w <= -delta, beta < 0)
    o_ref[...] = jnp.where(pos, alpha, jnp.where(neg, -alpha, 0.0)).astype(w.dtype)


def sb_quantize(
    w2d: jnp.ndarray,
    beta: jnp.ndarray,
    delta: jnp.ndarray,
    alpha: jnp.ndarray,
    block_rows: int = 8,
) -> jnp.ndarray:
    """Pallas signed-binary quantizer over filter-major weights.

    Args:
      w2d:   latent weights flattened per region, [G, E] (G regions, E
             elements per region = C_t * R * S).
      beta:  [G] sign factor per region (+1 / -1).
      delta: [G] threshold per region.
      alpha: [G] scale magnitude per region.
      block_rows: grid tile along G.
    Returns [G, E] quantized weights.
    """
    g, e = w2d.shape
    bg = min(block_rows, g)
    gp = _cdiv(g, bg) * bg
    pad = lambda v: jnp.pad(v.reshape(g, 1), ((0, gp - g), (0, 0)))
    out = pl.pallas_call(
        _sb_quantize_kernel,
        grid=(gp // bg,),
        in_specs=[
            pl.BlockSpec((bg, e), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bg, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, e), w2d.dtype),
        interpret=True,
    )(jnp.pad(w2d, ((0, gp - g), (0, 0))), pad(beta), pad(delta), pad(alpha))
    return out[:g]


def _ternary_quantize_kernel(w_ref, delta_ref, alpha_ref, o_ref):
    w = w_ref[...]
    delta = delta_ref[...]
    alpha = alpha_ref[...]
    o_ref[...] = jnp.where(
        w > delta, alpha, jnp.where(w < -delta, -alpha, 0.0)
    ).astype(w.dtype)


def ternary_quantize(
    w2d: jnp.ndarray, delta: jnp.ndarray, alpha: jnp.ndarray, block_rows: int = 8
) -> jnp.ndarray:
    """Pallas ternary quantizer (baseline), filter-major [K, E]."""
    g, e = w2d.shape
    bg = min(block_rows, g)
    gp = _cdiv(g, bg) * bg
    pad = lambda v: jnp.pad(v.reshape(g, 1), ((0, gp - g), (0, 0)))
    out = pl.pallas_call(
        _ternary_quantize_kernel,
        grid=(gp // bg,),
        in_specs=[
            pl.BlockSpec((bg, e), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bg, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, e), w2d.dtype),
        interpret=True,
    )(jnp.pad(w2d, ((0, gp - g), (0, 0))), pad(delta), pad(alpha))
    return out[:g]


def _binary_quantize_kernel(w_ref, alpha_ref, o_ref):
    w = w_ref[...]
    alpha = alpha_ref[...]
    o_ref[...] = jnp.where(w >= 0, alpha, -alpha).astype(w.dtype)


def binary_quantize(
    w2d: jnp.ndarray, alpha: jnp.ndarray, block_rows: int = 8
) -> jnp.ndarray:
    """Pallas binary (BWN) quantizer, filter-major [K, E]."""
    g, e = w2d.shape
    bg = min(block_rows, g)
    gp = _cdiv(g, bg) * bg
    out = pl.pallas_call(
        _binary_quantize_kernel,
        grid=(gp // bg,),
        in_specs=[
            pl.BlockSpec((bg, e), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bg, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, e), w2d.dtype),
        interpret=True,
    )(
        jnp.pad(w2d, ((0, gp - g), (0, 0))),
        jnp.pad(alpha.reshape(g, 1), ((0, gp - g), (0, 0))),
    )
    return out[:g]


# ---------------------------------------------------------------------------
# Signed-binary GEMM — the inference hot-spot
# ---------------------------------------------------------------------------


def _sb_matmul_kernel(a_ref, u_ref, beta_ref, o_ref, *, k_steps: int):
    """Grid (M/bm, N/bn, K/bk). Accumulate a_blk @ u_blk into the output
    block (resident across the K steps because the out index_map ignores
    k); on the last K step apply the per-column sign epilogue.

    On a real TPU the ``a`` and ``u`` blocks stream HBM->VMEM double
    buffered by the Pallas pipeline; the contraction hits the MXU with the
    {0, alpha} bitmap, which is exactly PLUM's "repetition first, sign
    later" schedule.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], u_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (o_ref[...] * beta_ref[...]).astype(o_ref.dtype)


def sb_matmul(
    a: jnp.ndarray,
    u: jnp.ndarray,
    beta: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """``(a @ u) * beta`` tiled for VMEM/MXU.

    a [M, K] activation patches (im2col), u [K, N] magnitude bitmap in
    {0, alpha_n}, beta [N] in {+1, -1}. Output [M, N].
    """
    m, kdim = a.shape
    k2, n = u.shape
    assert kdim == k2, (a.shape, u.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    # Zero-pad every dimension to a tile multiple: out-of-bounds reads in
    # the Pallas pipeline are undefined (NaN under interpret=True) and a
    # padded-K tail would poison the accumulator. Zero rows/cols are inert
    # under the contraction, so padding + final slice is exact.
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(kdim, bk) * bk
    a = jnp.pad(a, ((0, mp - m), (0, kp - kdim)))
    u = jnp.pad(u, ((0, kp - kdim), (0, np_ - n)))
    beta = jnp.pad(beta, ((0, np_ - n),), constant_values=1.0)
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)
    out = pl.pallas_call(
        functools.partial(_sb_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a, u, beta.reshape(1, np_))
    return out[:m, :n]


def sb_conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    beta: jnp.ndarray,
    delta_frac: float = 0.05,
    stride: int = 1,
    padding: int = 1,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """Full signed-binary conv block: quantize (Pallas) -> im2col ->
    sb_matmul (Pallas) -> reshape to NCHW.

    Used by the L2 model so the hot-spot lowers into the same HLO module.
    Inter-filter mode only (C_t = C): beta has one entry per filter.
    """
    from . import ref

    kk, c, r, s = w.shape
    nb, _, h, wd = x.shape
    w2d = w.reshape(kk, c * r * s)
    delta = delta_frac * jnp.max(jnp.abs(w2d), axis=1)
    bcol = beta.reshape(kk, 1)
    pos = jnp.logical_and(w2d >= delta[:, None], bcol >= 0)
    neg = jnp.logical_and(w2d <= -delta[:, None], bcol < 0)
    eff = jnp.logical_or(pos, neg).astype(w2d.dtype)
    denom = jnp.maximum(jnp.sum(eff, axis=1), 1.0)
    alpha = jnp.sum(jnp.abs(w2d) * eff, axis=1) / denom
    wq2d = sb_quantize(w2d, beta, delta, alpha)
    # magnitude bitmap + sign epilogue: u = |wq|^T, column sign = beta
    u = jnp.abs(wq2d).T  # [C*R*S, K]
    patches = ref.im2col_ref(x, r, s, stride, padding)  # [N*OH*OW, C*R*S]
    out = sb_matmul(patches, u, beta, bm=bm, bn=bn, bk=bk)  # [N*OH*OW, K]
    oh = (h + 2 * padding - r) // stride + 1
    ow = (wd + 2 * padding - s) // stride + 1
    return out.reshape(nb, oh * ow, kk).transpose(0, 2, 1).reshape(nb, kk, oh, ow)
