"""L1 performance analysis: VMEM footprint + MXU utilization estimates.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
Pallas kernel is optimized *structurally*: this module computes, for a
given `sb_matmul` tiling (bm, bn, bk) and problem size, the quantities
that determine real-TPU performance, and the AOT build asserts the
default tiling respects them (see test_analysis.py):

* VMEM working set: both pipeline buffers of each operand block plus the
  resident output block must fit in VMEM (~16 MiB/core on TPUv4; we
  budget half to leave room for Mosaic spills).
* MXU shape efficiency: blocks should be multiples of the 128x128
  systolic array; utilization = prod(effective/padded) per dimension.
* Arithmetic intensity (FLOPs per HBM byte) for the roofline position:
  the {0, alpha}-bitmap GEMM streams A and U once per grid step with the
  sign epilogue fused, so intensity ~ 2*bm*bn*bk / (bm*bk + bk*bn +
  bm*bn) elements.
"""

from __future__ import annotations

import dataclasses

MXU = 128                      # systolic array dimension (TPUv3/v4)
VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, TPUv4
VMEM_BUDGET = VMEM_BYTES // 2  # leave headroom for Mosaic
F32 = 4


@dataclasses.dataclass(frozen=True)
class TileAnalysis:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    vmem_fraction: float
    mxu_utilization: float
    arithmetic_intensity: float
    fits: bool


def _pad(x: int, to: int) -> int:
    return -(-x // to) * to


def analyze_tiling(bm: int, bn: int, bk: int, dtype_bytes: int = F32) -> TileAnalysis:
    """Analyze one (bm, bn, bk) block choice for the sb_matmul kernel."""
    # double-buffered A and U blocks (pallas pipeline), resident O block,
    # plus the 1 x bn sign row
    vmem = dtype_bytes * (2 * bm * bk + 2 * bk * bn + bm * bn + bn)
    mxu_util = (bm / _pad(bm, MXU)) * (bn / _pad(bn, MXU)) * (bk / _pad(bk, 8))
    flops = 2.0 * bm * bn * bk
    traffic = dtype_bytes * (bm * bk + bk * bn)  # O stays resident
    return TileAnalysis(
        bm=bm,
        bn=bn,
        bk=bk,
        vmem_bytes=vmem,
        vmem_fraction=vmem / VMEM_BYTES,
        mxu_utilization=mxu_util,
        arithmetic_intensity=flops / traffic,
        fits=vmem <= VMEM_BUDGET,
    )


def analyze_conv_as_gemm(n: int, c: int, h: int, w: int, k: int, r: int, s: int,
                         bm: int, bn: int, bk: int) -> dict:
    """Map a conv layer to the kernel GEMM and report padding waste from
    the real problem dims (M = N*OH*OW, K = C*R*S, N = K_filters)."""
    m_dim, k_dim, n_dim = n * h * w, c * r * s, k
    t = analyze_tiling(bm, bn, bk)
    grid = (-(-m_dim // bm), -(-n_dim // bn), -(-k_dim // bk))
    padded = grid[0] * bm * grid[1] * bn * grid[2] * bk
    return {
        "tile": t,
        "grid": grid,
        "pad_waste": 1.0 - (m_dim * k_dim * n_dim) / padded,
        "kernel_flops": 2.0 * m_dim * k_dim * n_dim,
    }


def default_tiling_report() -> TileAnalysis:
    """The kernel's shipped default (DEFAULT_BM/BN/BK in signed_binary.py)."""
    from . import signed_binary as sbk

    return analyze_tiling(sbk.DEFAULT_BM, sbk.DEFAULT_BN, sbk.DEFAULT_BK)


def best_tiling(max_candidates=(128, 256, 512)) -> TileAnalysis:
    """Exhaustive small search: the highest-arithmetic-intensity tiling
    that fits the VMEM budget at full MXU utilization."""
    best = None
    for bm in max_candidates:
        for bn in max_candidates:
            for bk in max_candidates:
                t = analyze_tiling(bm, bn, bk)
                if not t.fits or t.mxu_utilization < 0.999:
                    continue
                if best is None or t.arithmetic_intensity > best.arithmetic_intensity:
                    best = t
    return best
