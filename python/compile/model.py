"""L2: JAX model definitions (fwd/bwd) for the PLUM reproduction.

A single imperative graph-builder (:class:`Tape`) both *initializes*
parameters (numpy RNG, deterministic per seed) and *applies* the network,
so init and apply can never drift apart. Parameters, BN state and constant
buffers (region sign factors ``beta``) live in flat ``name -> array``
dicts; the AOT manifest records the sorted-name order, which is exactly
jax's dict flattening order, so the rust runtime can marshal literals
positionally.

Architectures (paper §4):
  * ``cifar_resnet`` — He et al. CIFAR ResNet, depth 6n+2, option-A
    shortcuts; stem and final fc stay full-precision (paper supp. C).
  * ``resnet18``     — basic-block ResNet-18 for 64px inputs with
    projection shortcuts (quantized).
  * ``vgg_small`` / ``alexnet_small`` — VGG** / AlexNet* derivatives used
    in Table 6.

Training follows the paper: Adam, no weight decay, latent weights clamped
to [-1, 1] after every update (the clamp produces the +-1 peaks in
Figure 6b), EDE schedule driven by a ``progress`` input in [0, 1].
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import common, quant
from .kernels import ref
from .kernels import signed_binary as sbk

BN_MOMENTUM = 0.9
BN_EPS = 1e-5
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


class Tape:
    """Builds and/or applies the network layer by layer.

    mode == 'init' : creates parameters with a numpy RNG; input is zeros.
    mode == 'apply': consumes params/bn/consts dicts; records BN updates.
    """

    def __init__(self, cfg: common.ModelConfig, mode: str, seed: int = 0,
                 params=None, bn=None, consts=None, train=True,
                 progress=None, use_pallas_infer=False):
        self.cfg = cfg
        self.mode = mode
        self.rng = np.random.RandomState(seed)
        self.params: Dict[str, jnp.ndarray] = params if params is not None else {}
        self.bn: Dict[str, jnp.ndarray] = bn if bn is not None else {}
        self.consts: Dict[str, jnp.ndarray] = consts if consts is not None else {}
        self.new_bn: Dict[str, jnp.ndarray] = {}
        self.train = train
        self.progress = progress if progress is not None else jnp.float32(0.0)
        self.use_pallas_infer = use_pallas_infer
        self.idx = 0
        self.quantized_names: List[str] = []
        self.conv_log: List[dict] = []   # layer geometry for the manifest
        self.quantizer = quant.make_quantizer(cfg)

    # -- parameter plumbing -------------------------------------------------

    def _next(self, kind: str) -> str:
        name = f"{self.idx:03d}.{kind}"
        self.idx += 1
        return name

    def _param(self, name: str, shape, init_fn):
        if self.mode == "init":
            self.params[name] = jnp.asarray(init_fn(shape), jnp.float32)
        return self.params[name]

    def _const(self, name: str, value_fn):
        if self.mode == "init":
            self.consts[name] = jnp.asarray(value_fn(), jnp.float32)
        return self.consts[name]

    def _he(self, shape):
        fan_in = int(np.prod(shape[1:]))
        return self.rng.randn(*shape).astype(np.float32) * np.sqrt(2.0 / fan_in)

    # -- layers --------------------------------------------------------------

    def conv(self, x, out_ch: int, ksize: int = 3, stride: int = 1,
             quantized: bool = True):
        """Conv2d NCHW/OIHW; quantized per cfg.scheme unless excluded."""
        name = self._next("conv")
        in_ch = x.shape[1]
        pad = ksize // 2
        if self.mode == "init":
            self.conv_log.append(dict(
                name=name, k=out_ch, c=int(in_ch), r=ksize, s=ksize,
                stride=stride, padding=pad, h=int(x.shape[2]), w=int(x.shape[3]),
                quantized=bool(quantized and self.cfg.scheme != "fp"),
            ))
        w = self._param(name + ".w", (out_ch, in_ch, ksize, ksize), self._he)
        if quantized and self.cfg.scheme != "fp":
            self.quantized_names.append(name + ".w")
            g = self.cfg.regions_per_filter if self.cfg.scheme == "sb" else 1
            beta = self._const(
                name + ".beta",
                lambda: ref.default_beta(out_ch * g, self.cfg.p_pos),
            )
            if (self.cfg.scheme == "sb" and not self.train
                    and self.use_pallas_infer and g == 1):
                # Inference hot path: the L1 Pallas signed-binary GEMM.
                return sbk.sb_conv2d(
                    x, w, beta, self.cfg.delta_frac, stride, pad
                )
            wq = self.quantizer(w, beta, self.progress)
        else:
            wq = w
        return ref.conv2d_ref(x, wq, stride, pad)

    def batch_norm(self, x):
        name = self._next("bn")
        c = x.shape[1]
        gamma = self._param(name + ".gamma", (c,), lambda s: np.ones(s, np.float32))
        bias = self._param(name + ".bias", (c,), lambda s: np.zeros(s, np.float32))
        if self.mode == "init":
            self.bn[name + ".mean"] = jnp.zeros((c,), jnp.float32)
            self.bn[name + ".var"] = jnp.ones((c,), jnp.float32)
        r_mean = self.bn[name + ".mean"]
        r_var = self.bn[name + ".var"]
        if self.train:
            mean = jnp.mean(x, axis=(0, 2, 3))
            var = jnp.var(x, axis=(0, 2, 3))
            self.new_bn[name + ".mean"] = BN_MOMENTUM * r_mean + (1 - BN_MOMENTUM) * mean
            self.new_bn[name + ".var"] = BN_MOMENTUM * r_var + (1 - BN_MOMENTUM) * var
        else:
            mean, var = r_mean, r_var
            self.new_bn[name + ".mean"] = r_mean
            self.new_bn[name + ".var"] = r_var
        inv = jax.lax.rsqrt(var + BN_EPS)
        shape = (1, -1, 1, 1)
        return (x - mean.reshape(shape)) * (inv * gamma).reshape(shape) + bias.reshape(shape)

    def activation(self, x):
        act = self.cfg.act
        if act == "relu":
            return jax.nn.relu(x)
        if act == "tanh":
            return jnp.tanh(x)
        if act == "lrelu":
            return jax.nn.leaky_relu(x, 0.01)
        # prelu: learned per-channel slope (He et al. 2015)
        name = self._next("prelu")
        c = x.shape[1]
        a = self._param(name + ".a", (c,), lambda s: np.full(s, 0.25, np.float32))
        return jnp.where(x >= 0, x, x * a.reshape(1, -1, 1, 1))

    def avg_pool2(self, x):
        return jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
        ) / 4.0

    def max_pool2(self, x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
        )

    def global_avg_pool(self, x):
        return jnp.mean(x, axis=(2, 3))

    def fc(self, x, out_dim: int):
        name = self._next("fc")
        in_dim = x.shape[-1]
        w = self._param(
            name + ".w", (in_dim, out_dim),
            lambda s: self.rng.randn(*s).astype(np.float32) * 0.01,
        )
        b = self._param(name + ".b", (out_dim,), lambda s: np.zeros(s, np.float32))
        return x @ w + b

    # -- blocks ---------------------------------------------------------------

    def basic_block(self, x, out_ch: int, stride: int, projection: bool):
        """conv-bn-act-conv-bn + shortcut, then act."""
        y = self.conv(x, out_ch, 3, stride)
        y = self.batch_norm(y)
        y = self.activation(y)
        y = self.conv(y, out_ch, 3, 1)
        y = self.batch_norm(y)
        if stride != 1 or x.shape[1] != out_ch:
            if projection:
                sc = self.conv(x, out_ch, 1, stride)
                sc = self.batch_norm(sc)
            else:
                # option-A: strided subsample + zero-pad channels (no params)
                sc = x[:, :, ::stride, ::stride]
                pad_c = out_ch - x.shape[1]
                sc = jnp.pad(sc, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
        else:
            sc = x
        return self.activation(y + sc)

    # -- whole nets ------------------------------------------------------------

    def forward(self, x):
        cfg = self.cfg
        if cfg.arch == "cifar_resnet":
            n = (cfg.depth - 2) // 6
            widths = common.cifar_stage_widths(cfg.width_mult)
            # stem is full precision (paper supp. C)
            y = self.conv(x, widths[0], 3, 1, quantized=False)
            y = self.batch_norm(y)
            y = self.activation(y)
            for si, w in enumerate(widths):
                for bi in range(n):
                    stride = 2 if (si > 0 and bi == 0) else 1
                    y = self.basic_block(y, w, stride, projection=False)
            y = self.global_avg_pool(y)
            return self.fc(y, cfg.num_classes)
        if cfg.arch == "resnet18":
            widths = common.resnet18_stage_widths(cfg.width_mult)
            y = self.conv(x, widths[0], 3, 1, quantized=False)
            y = self.batch_norm(y)
            y = self.activation(y)
            for si, w in enumerate(widths):
                for bi in range(2):
                    stride = 2 if (si > 0 and bi == 0) else 1
                    y = self.basic_block(y, w, stride, projection=True)
            y = self.global_avg_pool(y)
            return self.fc(y, cfg.num_classes)
        if cfg.arch in ("vgg_small", "alexnet_small"):
            plan = (common.vgg_small_plan(cfg.width_mult)
                    if cfg.arch == "vgg_small"
                    else common.alexnet_small_plan(cfg.width_mult))
            y = x
            first_conv = True
            for kind, ch in plan:
                if kind == "pool":
                    y = self.max_pool2(y)
                else:
                    y = self.conv(y, ch, 3, 1, quantized=not first_conv)
                    y = self.batch_norm(y)
                    y = self.activation(y)
                    first_conv = False
            y = self.global_avg_pool(y)
            return self.fc(y, cfg.num_classes)
        raise ValueError(cfg.arch)


# ---------------------------------------------------------------------------
# init / apply / loss / train step
# ---------------------------------------------------------------------------


def init(cfg: common.ModelConfig, seed: int = 0):
    """Create (params, bn_state, consts, quantized_names, conv_log)."""
    tape = Tape(cfg, "init", seed=seed, train=True)
    x = jnp.zeros((1, cfg.in_channels, cfg.image_size, cfg.image_size), jnp.float32)
    tape.forward(x)
    return tape.params, tape.bn, tape.consts, tape.quantized_names, tape.conv_log


def apply_model(cfg, params, bn, consts, x, train: bool, progress,
                use_pallas_infer: bool = False):
    """Run the network; returns (logits, new_bn_state)."""
    tape = Tape(cfg, "apply", params=params, bn=bn, consts=consts,
                train=train, progress=progress,
                use_pallas_infer=use_pallas_infer)
    logits = tape.forward(x)
    return logits, tape.new_bn


def loss_and_acc(cfg, params, bn, consts, x, y, progress):
    logits, new_bn = apply_model(cfg, params, bn, consts, x, True, progress)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss, (acc, new_bn)


def sorted_names(d: Dict[str, jnp.ndarray]) -> List[str]:
    return sorted(d.keys())


def make_train_step(cfg: common.ModelConfig, quantized_names: List[str]):
    """Returns fn(params, bn, consts, m, v, x, y, lr, step, progress).

    Outputs (loss, acc, params', bn', m', v'). Latent weights of quantized
    convs are clamped to [-1, 1] after the Adam update (paper Fig. 6b).
    All dicts flatten in sorted-key order — the manifest contract.
    """
    qset = frozenset(quantized_names)

    def step_fn(params, bn, consts, m, v, x, y, lr, step, progress):
        grad_fn = jax.value_and_grad(
            lambda p: loss_and_acc(cfg, p, bn, consts, x, y, progress),
            has_aux=True,
        )
        (loss, (acc, new_bn)), grads = grad_fn(params)
        b1t = jnp.power(ADAM_B1, step)
        b2t = jnp.power(ADAM_B2, step)
        new_params, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            mk = ADAM_B1 * m[k] + (1 - ADAM_B1) * g
            vk = ADAM_B2 * v[k] + (1 - ADAM_B2) * g * g
            mhat = mk / (1 - b1t)
            vhat = vk / (1 - b2t)
            p = params[k] - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
            if k in qset:
                p = jnp.clip(p, -1.0, 1.0)
            new_params[k] = p
            new_m[k] = mk
            new_v[k] = vk
        return loss, acc, new_params, new_bn, new_m, new_v

    return step_fn


def make_infer(cfg: common.ModelConfig, use_pallas: bool = True):
    """Returns fn(params, bn, consts, x) -> logits (eval mode)."""

    def infer_fn(params, bn, consts, x):
        logits, _ = apply_model(
            cfg, params, bn, consts, x, False, jnp.float32(1.0),
            use_pallas_infer=use_pallas,
        )
        return logits

    return infer_fn


def param_counts(cfg, params, consts, quantized_names):
    """(total_params, quantized_params, effectual_estimate).

    Effectual = non-zero after quantization of the *initial* weights; the
    trained number is computed by the rust side from the checkpoint.
    """
    total = int(sum(int(np.prod(p.shape)) for p in params.values()))
    qtotal, eff = 0, 0
    qz = quant.make_quantizer(cfg)
    for name in quantized_names:
        w = params[name]
        qtotal += int(np.prod(w.shape))
        beta = consts.get(name.replace(".w", ".beta"))
        if cfg.scheme == "fp":
            eff += int(np.prod(w.shape))
        else:
            wq = qz(w, beta if beta is not None else jnp.zeros(()), jnp.float32(1.0))
            eff += int(jnp.sum(jnp.abs(wq) > 0))
    return total, qtotal, eff
