"""Trainable quantizers (forward + backward) for PLUM and baselines.

Each factory returns a differentiable ``q(w, beta, progress) -> wq``
closure with a custom VJP implementing the paper's backward pass:

* STE (paper eq. 4): gradient scaled by alpha on the effectual branch and
  passed through (x1) on the ineffectual branch.
* Adapted EDE (paper §3.2.3, Table 3): when enabled, the backward uses
  ``g'(x) = k t (1 - tanh^2(t (x -+ Delta)))`` centred at the region's own
  threshold (+Delta for {0,+1} regions, -Delta for {0,-1}), with
  ``t = Tmin * 10^(progress * log10(Tmax/Tmin))`` and ``k = max(1/t, 1)``
  driven by the training ``progress`` scalar in [0, 1].

The *forward* pass routes through the L1 Pallas kernels so that quantize
semantics in the train/infer HLO artifacts are the kernel's, not a copy.
``beta`` is a constant buffer (the paper fixes region signs before
training); its cotangent is zeroed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import signed_binary as sbk


def _filter_stats_sb(w2d, beta, delta_frac):
    """Per-region (delta, alpha) for signed-binary, filter-major [G, E]."""
    delta = delta_frac * jnp.max(jnp.abs(w2d), axis=1)
    bcol = beta.reshape(-1, 1)
    pos = jnp.logical_and(w2d >= delta[:, None], bcol >= 0)
    neg = jnp.logical_and(w2d <= -delta[:, None], bcol < 0)
    eff = jnp.logical_or(pos, neg).astype(w2d.dtype)
    denom = jnp.maximum(jnp.sum(eff, axis=1), 1.0)
    alpha = jnp.sum(jnp.abs(w2d) * eff, axis=1) / denom
    return delta, alpha


def make_sb_quantizer(delta_frac: float, regions_per_filter: int,
                      use_ede: bool, t_min: float = 0.1, t_max: float = 10.0,
                      standardize: str = "none"):
    """Signed-binary quantizer q(w[K,C,R,S], beta[K*G], progress) -> wq.

    ``standardize`` (Table 9): "local" standardizes latent weights per
    signed-binary region, "global" per layer, before thresholding.
    """

    g_regions = regions_per_filter

    def _forward(w, beta):
        k, c, r, s = w.shape
        if standardize == "global":
            w = (w - jnp.mean(w)) / (jnp.std(w) + 1e-8)
        wr = ref.sb_region_reshape(w, g_regions)
        if standardize == "local":
            mu = jnp.mean(wr, axis=(1, 2, 3), keepdims=True)
            sd = jnp.std(wr, axis=(1, 2, 3), keepdims=True) + 1e-8
            wr = (wr - mu) / sd
        w2d = wr.reshape(wr.shape[0], -1)
        delta, alpha = _filter_stats_sb(w2d, beta, delta_frac)
        wq2d = sbk.sb_quantize(w2d, beta, delta, alpha)
        return ref.sb_region_unshape(
            wq2d.reshape(wr.shape), k, c, g_regions
        ), (delta, alpha)

    @jax.custom_vjp
    def q(w, beta, progress):
        return _forward(w, beta)[0]

    def q_fwd(w, beta, progress):
        wq, (delta, alpha) = _forward(w, beta)
        return wq, (w, beta, delta, alpha, progress)

    def q_bwd(res, gout):
        w, beta, delta, alpha, progress = res
        k, c, r, s = w.shape
        wr = ref.sb_region_reshape(w, g_regions)
        gr = ref.sb_region_reshape(gout, g_regions)
        bcol = beta.reshape(-1, 1, 1, 1)
        dcol = delta.reshape(-1, 1, 1, 1)
        acol = alpha.reshape(-1, 1, 1, 1)
        if use_ede:
            # EDE replaces the STE derivative entirely (IR-Net, adapted to
            # the shifted centre +-Delta).
            t, kk = ref.ede_t_k(progress, t_min, t_max)
            centre = jnp.where(bcol >= 0, dcol, -dcol)
            scale = kk * t * (1.0 - jnp.tanh(t * (wr - centre)) ** 2)
        else:
            # paper eq. (4): alpha-scaled on the effectual branch, 1x pass
            # through otherwise.
            pos = jnp.logical_and(wr > dcol, bcol >= 0)
            neg = jnp.logical_and(wr < -dcol, bcol < 0)
            scale = jnp.where(jnp.logical_or(pos, neg), acol, 1.0)
        gw = ref.sb_region_unshape(gr * scale, k, c, g_regions)
        return gw, jnp.zeros_like(beta), jnp.zeros_like(progress)

    q.defvjp(q_fwd, q_bwd)
    return q


def make_binary_quantizer(use_ede: bool, t_min: float = 0.1, t_max: float = 10.0):
    """BWN binary quantizer with clipped-STE / EDE backward."""

    def _forward(w):
        k = w.shape[0]
        w2d = w.reshape(k, -1)
        alpha = jnp.mean(jnp.abs(w2d), axis=1)
        wq2d = sbk.binary_quantize(w2d, alpha)
        return wq2d.reshape(w.shape), alpha

    @jax.custom_vjp
    def q(w, beta, progress):
        return _forward(w)[0]

    def q_fwd(w, beta, progress):
        wq, alpha = _forward(w)
        return wq, (w, alpha, beta, progress)

    def q_bwd(res, gout):
        w, alpha, beta, progress = res
        acol = alpha.reshape(-1, 1, 1, 1)
        if use_ede:
            t, kk = ref.ede_t_k(progress, t_min, t_max)
            scale = kk * t * (1.0 - jnp.tanh(t * w) ** 2)
        else:
            # clipped STE (BinaryConnect): pass-through inside [-1, 1],
            # alpha-scaled like eq. (4)'s effectual branch.
            scale = jnp.where(jnp.abs(w) <= 1.0, acol, 0.0)
        return gout * scale, jnp.zeros_like(beta), jnp.zeros_like(progress)

    q.defvjp(q_fwd, q_bwd)
    return q


def make_ternary_quantizer(delta_frac: float, use_ede: bool,
                           t_min: float = 0.1, t_max: float = 10.0):
    """TWN ternary quantizer with the paper's Delta rule."""

    def _forward(w):
        k = w.shape[0]
        w2d = w.reshape(k, -1)
        delta = delta_frac * jnp.max(jnp.abs(w2d), axis=1)
        mask = (jnp.abs(w2d) > delta[:, None]).astype(w2d.dtype)
        denom = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
        alpha = jnp.sum(jnp.abs(w2d) * mask, axis=1) / denom
        wq2d = sbk.ternary_quantize(w2d, delta, alpha)
        return wq2d.reshape(w.shape), (delta, alpha)

    @jax.custom_vjp
    def q(w, beta, progress):
        return _forward(w)[0]

    def q_fwd(w, beta, progress):
        wq, (delta, alpha) = _forward(w)
        return wq, (w, delta, alpha, beta, progress)

    def q_bwd(res, gout):
        w, delta, alpha, beta, progress = res
        dcol = delta.reshape(-1, 1, 1, 1)
        acol = alpha.reshape(-1, 1, 1, 1)
        if use_ede:
            t, kk = ref.ede_t_k(progress, t_min, t_max)
            # two transition centres at +-Delta; take the nearer one.
            centre = jnp.where(w >= 0, dcol, -dcol)
            scale = kk * t * (1.0 - jnp.tanh(t * (w - centre)) ** 2)
        else:
            scale = jnp.where(jnp.abs(w) > dcol, acol, 1.0)
        return gout * scale, jnp.zeros_like(beta), jnp.zeros_like(progress)

    q.defvjp(q_fwd, q_bwd)
    return q


def make_quantizer(cfg):
    """Dispatch on cfg.scheme; 'fp' returns identity (beta ignored)."""
    if cfg.scheme == "fp":
        return lambda w, beta, progress: w
    if cfg.scheme == "binary":
        return make_binary_quantizer(cfg.use_ede, cfg.ede_t_min, cfg.ede_t_max)
    if cfg.scheme == "ternary":
        return make_ternary_quantizer(
            cfg.delta_frac, cfg.use_ede, cfg.ede_t_min, cfg.ede_t_max
        )
    if cfg.scheme == "sb":
        return make_sb_quantizer(
            cfg.delta_frac, cfg.regions_per_filter, cfg.use_ede,
            cfg.ede_t_min, cfg.ede_t_max,
            standardize=getattr(cfg, "standardize", "none"),
        )
    raise ValueError(cfg.scheme)
