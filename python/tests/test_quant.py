"""L2 trainable quantizers: forward matches oracle; backward implements
the paper's STE (eq. 4) / adapted EDE (§3.2.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, quant
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def w_fixture(seed=0, shape=(6, 8, 3, 3)):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(np.float32))


def test_sb_forward_matches_oracle():
    w = w_fixture()
    beta = ref.default_beta(6, 0.5)
    q = quant.make_sb_quantizer(0.05, 1, use_ede=True)
    np.testing.assert_allclose(
        np.asarray(q(w, beta, jnp.float32(0.3))),
        np.asarray(ref.signed_binary_quantize_ref(w, beta, 0.05)),
        rtol=1e-5, atol=1e-6,
    )


def test_binary_ternary_forward_match_oracle():
    w = w_fixture(1)
    beta = jnp.zeros((6,))
    qb = quant.make_binary_quantizer(use_ede=False)
    np.testing.assert_allclose(
        np.asarray(qb(w, beta, jnp.float32(0.0))),
        np.asarray(ref.binary_quantize_ref(w)),
        rtol=1e-5, atol=1e-6,
    )
    qt = quant.make_ternary_quantizer(0.05, use_ede=False)
    np.testing.assert_allclose(
        np.asarray(qt(w, beta, jnp.float32(0.0))),
        np.asarray(ref.ternary_quantize_ref(w, 0.05)),
        rtol=1e-5, atol=1e-6,
    )


def test_sb_ste_gradient_eq4():
    """With EDE off, dL/dw = alpha on the effectual branch, 1 elsewhere."""
    w = w_fixture(2)
    beta = ref.default_beta(6, 0.5)
    q = quant.make_sb_quantizer(0.05, 1, use_ede=False)
    g = jax.grad(lambda w_: jnp.sum(q(w_, beta, jnp.float32(0.0))))(w)
    wq = ref.signed_binary_quantize_ref(w, beta, 0.05)
    g_np, wq_np, w_np = map(np.asarray, (g, wq, w))
    eff = wq_np != 0
    # effectual positions: gradient equals |alpha| (value magnitude)
    np.testing.assert_allclose(g_np[eff], np.abs(wq_np[eff]), rtol=1e-4)
    # strictly-interior ineffectual positions pass through at 1.0
    ineff = ~eff
    np.testing.assert_allclose(g_np[ineff], np.ones_like(g_np[ineff]), rtol=1e-5)


def test_sb_ede_gradient_peaks_at_threshold():
    """EDE derivative is largest near the region's own +-Delta centre."""
    k, c = 2, 64
    w = w_fixture(3, (k, c, 3, 3))
    beta = jnp.asarray([1.0, -1.0])
    q = quant.make_sb_quantizer(0.05, 1, use_ede=True)
    progress = jnp.float32(1.0)  # t = 10: sharply peaked
    g = jax.grad(lambda w_: jnp.sum(q(w_, beta, progress)))(w)
    g_np, w_np = np.asarray(g), np.asarray(w)
    delta = 0.05 * np.abs(w_np.reshape(k, -1)).max(axis=1)
    for fi, centre in [(0, delta[0]), (1, -delta[1])]:
        near = np.abs(w_np[fi] - centre) < 0.02
        far = np.abs(w_np[fi] - centre) > 0.5
        if near.any() and far.any():
            assert g_np[fi][near].mean() > 5 * g_np[fi][far].mean()


def test_beta_and_progress_get_zero_grads():
    w = w_fixture(4)
    beta = ref.default_beta(6, 0.5)
    q = quant.make_sb_quantizer(0.05, 1, use_ede=True)
    gb = jax.grad(lambda b: jnp.sum(q(w, b, jnp.float32(0.5))))(beta)
    assert float(jnp.abs(gb).max()) == 0.0


def test_standardize_variants_run():
    w = w_fixture(5)
    beta = ref.default_beta(6, 0.5)
    for std in ("none", "local", "global"):
        q = quant.make_sb_quantizer(0.05, 1, use_ede=True, standardize=std)
        out = q(w, beta, jnp.float32(0.1))
        assert out.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(out)))


def test_dispatch_matches_config():
    for scheme in ("fp", "binary", "ternary", "sb"):
        cfg = common.ModelConfig(name="t", scheme=scheme, depth=8, image_size=16)
        q = quant.make_quantizer(cfg)
        w = w_fixture(6)
        beta = ref.default_beta(6, 0.5)
        out = q(w, beta, jnp.float32(0.0))
        assert out.shape == w.shape
        if scheme == "fp":
            np.testing.assert_array_equal(np.asarray(out), np.asarray(w))
