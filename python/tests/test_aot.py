"""AOT emitter: manifest/params.bin consistency and HLO-text validity for
one small config (full-grid emission is exercised by `make artifacts`)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, common, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = common.ModelConfig(name="t_aot", depth=8, image_size=12, batch_size=2)
    manifest = aot.emit_model(cfg, str(out), train=True)
    return out, cfg, manifest


def test_files_exist(emitted):
    out, cfg, man = emitted
    for f in man["files"].values():
        assert (out / f).exists(), f


def test_hlo_text_is_parseable_hlo(emitted):
    out, cfg, man = emitted
    text = (out / man["files"]["train"]).read_text()
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    infer = (out / man["files"]["infer"]).read_text()
    assert infer.startswith("HloModule")
    # the pallas sb path lowers to while loops in the infer graph
    assert "while" in infer


def test_params_bin_matches_manifest(emitted):
    out, cfg, man = emitted
    blob = (out / man["files"]["params"]).read_bytes()
    state = [e for e in man["train_inputs"] if e["group"] in ("params", "bn", "consts")]
    total = sum(int(np.prod(e["shape"] or [1])) if e["shape"] else 1 for e in state)
    assert len(blob) == 4 * total


def test_signature_order_contract(emitted):
    _, cfg, man = emitted
    groups = [e["group"] for e in man["train_inputs"]]
    # params... bn... consts... opt_m... opt_v... input x, y, hyper x3
    order = ["params", "bn", "consts", "opt_m", "opt_v", "input", "hyper"]
    filtered = [g for g in order for _ in range(groups.count(g))]
    assert groups == filtered, "groups must be contiguous and ordered"
    names = [e["name"] for e in man["train_inputs"] if e["group"] == "params"]
    assert names == sorted(names), "params must be sorted by name"
    tail = [e["name"] for e in man["train_inputs"][-3:]]
    assert tail == ["lr", "step", "progress"]


def test_outputs_mirror_inputs(emitted):
    _, cfg, man = emitted
    out_groups = [e["group"] for e in man["train_outputs"]]
    assert out_groups[:2] == ["metric", "metric"]
    n_params = sum(1 for e in man["train_inputs"] if e["group"] == "params")
    assert out_groups.count("params") == n_params
    assert out_groups.count("opt_m") == n_params
    assert out_groups.count("opt_v") == n_params


def test_conv_layers_recorded(emitted):
    _, cfg, man = emitted
    layers = man["conv_layers"]
    assert layers[0]["quantized"] is False
    assert all(l["quantized"] for l in layers[1:])
    assert layers[0]["h"] == cfg.image_size


def test_index_structure():
    cfgs, index = aot.build_config_set("default")

    def names(node, keys):
        for k in keys:
            v = node[k]
            assert isinstance(v, str)
            yield v

    referenced = []
    for row in index["table1"]:
        referenced += list(names(row, ["fp", "binary", "ternary", "sb"]))
    referenced += [e["cfg"] for e in index["table2"]]
    referenced += list(names(index["table3"], ["enabled", "disabled"]))
    referenced += list(names(index["table4"], ["ct_c", "ct_c2"]))
    referenced += list(names(index["table5"], ["d005", "d001"]))
    for row in index["table6"]:
        referenced += list(names(row, ["sb", "fp"]))
    referenced += list(names(index["table7"]["depth"], ["sb_d32", "b_d32", "b_d20"]))
    referenced += list(names(index["table7"]["width"], ["sb_w10", "b_w10", "b_w07"]))
    referenced += list(index["table8a"].values()) + list(index["table8b"].values())
    referenced += list(names(index["table9"], ["none", "local", "global"]))
    referenced += list(names(index["table10"], ["p100", "p025", "p050"]))
    referenced += list(names(index["table11"], ["enabled", "disabled"]))
    referenced += list(names(index["table12"], ["d005", "d001"]))
    referenced += [index["serving"], index["e2e"]]
    for name in referenced:
        assert name in cfgs, name

    # full set is a superset
    full_cfgs, _ = aot.build_config_set("full")
    assert set(cfgs).issubset(set(full_cfgs))


# ---------------------------------------------------------------------------
# L2 perf-structure guardrails (§Perf): the lowered HLO must not duplicate
# work — quantization appears once per layer per pass, convs appear only
# fwd + dgrad + wgrad, and the sb infer path runs GEMMs (dot), not
# convolutions, for quantized layers.
# ---------------------------------------------------------------------------


def _count(text, token):
    return sum(1 for line in text.splitlines() if f" {token}(" in line or f"= {token}(" in line)


def test_train_hlo_conv_count(emitted):
    out, cfg, man = emitted
    text = (out / man["files"]["train"]).read_text()
    n_convs = len(man["conv_layers"])
    convs = text.count(" convolution(")
    # fwd + input-grad + weight-grad per conv (stem has no input grad)
    assert convs <= 3 * n_convs, f"{convs} convolutions for {n_convs} layers"
    assert convs >= 2 * n_convs


def test_infer_hlo_uses_gemm_hot_path(emitted):
    out, cfg, man = emitted
    text = (out / man["files"]["infer"]).read_text()
    n_quant = sum(1 for l in man["conv_layers"] if l["quantized"])
    # quantized layers lower to dot (im2col GEMM inside the pallas loop);
    # only the fp stem remains a convolution
    convs = text.count(" convolution(")
    assert convs <= len(man["conv_layers"]) - n_quant + 1, (
        f"{convs} convolutions — quantized layers escaped the pallas GEMM path"
    )


def test_train_hlo_no_duplicate_quantize(emitted):
    out, cfg, man = emitted
    text = (out / man["files"]["train"]).read_text()
    n_quant = sum(1 for l in man["conv_layers"] if l["quantized"])
    # each sb quantizer computes one per-region max(|w|); XLA folds each
    # into a small number of reduce ops. A blow-up here means the
    # quantizer is being recomputed per use.
    reduces = text.count(" reduce(")
    assert reduces < 40 * max(n_quant, 1), f"{reduces} reduces for {n_quant} quant layers"
