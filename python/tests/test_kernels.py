"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/blocks; assert_allclose against ref — the CORE
correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import signed_binary as sbk

jax.config.update("jax_platform_name", "cpu")

SET = settings(max_examples=20, deadline=None)


def randn(rng, *shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


# ---------------------------------------------------------------------------
# sb_matmul vs oracle
# ---------------------------------------------------------------------------


@SET
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 60),
    n=st.integers(1, 24),
    bm=st.sampled_from([2, 4, 8, 128]),
    bn=st.sampled_from([2, 4, 128]),
    bk=st.sampled_from([3, 8, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sb_matmul_matches_ref(m, k, n, bm, bn, bk, seed):
    rng = np.random.RandomState(seed)
    a = randn(rng, m, k)
    u = jnp.abs(randn(rng, k, n)) * (randn(rng, k, n) > 0)
    beta = ref.default_beta(n, 0.5)
    got = sbk.sb_matmul(a, u, beta, bm=bm, bn=bn, bk=bk)
    want = ref.sb_matmul_ref(a, u, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_sb_matmul_zero_bitmap_gives_zero():
    a = jnp.ones((8, 16))
    u = jnp.zeros((16, 4))
    beta = ref.default_beta(4, 0.5)
    out = sbk.sb_matmul(a, u, beta, bm=4, bn=2, bk=8)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# quantize kernels vs oracle
# ---------------------------------------------------------------------------


@SET
@given(
    k=st.integers(1, 16),
    c=st.integers(1, 16),
    r=st.sampled_from([1, 3]),
    p_pos=st.sampled_from([0.0, 0.25, 0.5, 1.0]),
    block=st.sampled_from([1, 3, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sb_quantize_matches_ref(k, c, r, p_pos, block, seed):
    rng = np.random.RandomState(seed)
    w = randn(rng, k, c, r, r)
    beta = ref.default_beta(k, p_pos)
    want = ref.signed_binary_quantize_ref(w, beta, 0.05)
    # kernel path: compute stats like the quantizer module does
    w2d = w.reshape(k, -1)
    delta = 0.05 * jnp.max(jnp.abs(w2d), axis=1)
    bcol = beta.reshape(k, 1)
    pos = jnp.logical_and(w2d >= delta[:, None], bcol >= 0)
    neg = jnp.logical_and(w2d <= -delta[:, None], bcol < 0)
    eff = jnp.logical_or(pos, neg).astype(w2d.dtype)
    denom = jnp.maximum(jnp.sum(eff, axis=1), 1.0)
    alpha = jnp.sum(jnp.abs(w2d) * eff, axis=1) / denom
    got = sbk.sb_quantize(w2d, beta, delta, alpha, block_rows=block).reshape(w.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@SET
@given(
    k=st.integers(1, 12),
    e=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_ternary_kernels_match_ref(k, e, seed):
    rng = np.random.RandomState(seed)
    w = randn(rng, k, e, 1, 1)
    wb = ref.binary_quantize_ref(w)
    w2d = w.reshape(k, -1)
    alpha = jnp.mean(jnp.abs(w2d), axis=1)
    got_b = sbk.binary_quantize(w2d, alpha).reshape(w.shape)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(wb), rtol=1e-5, atol=1e-6)

    wt = ref.ternary_quantize_ref(w, 0.05)
    delta = 0.05 * jnp.max(jnp.abs(w2d), axis=1)
    mask = (jnp.abs(w2d) > delta[:, None]).astype(w2d.dtype)
    denom = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    alpha_t = jnp.sum(jnp.abs(w2d) * mask, axis=1) / denom
    got_t = sbk.ternary_quantize(w2d, delta, alpha_t).reshape(w.shape)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(wt), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# sb_conv2d (full hot path) vs oracle
# ---------------------------------------------------------------------------


@SET
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 12),
    hw=st.integers(3, 10),
    k=st.integers(1, 12),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sb_conv2d_matches_ref(n, c, hw, k, stride, seed):
    rng = np.random.RandomState(seed)
    x = randn(rng, n, c, hw, hw)
    w = randn(rng, k, c, 3, 3)
    beta = ref.default_beta(k, 0.5)
    got = sbk.sb_conv2d(x, w, beta, stride=stride, bm=16, bn=4, bk=32)
    want = ref.sb_conv2d_ref(x, w, beta, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_im2col_matches_lax_conv():
    rng = np.random.RandomState(0)
    x = randn(rng, 2, 4, 6, 6)
    w = randn(rng, 5, 4, 3, 3)
    patches = ref.im2col_ref(x, 3, 3, 1, 1)
    w2d = w.reshape(5, -1).T
    out = (patches @ w2d).reshape(2, 36, 5).transpose(0, 2, 1).reshape(2, 5, 6, 6)
    want = ref.conv2d_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quantizer semantics (oracle-level invariants)
# ---------------------------------------------------------------------------


@SET
@given(
    k=st.integers(1, 10),
    c=st.integers(1, 10),
    p_pos=st.sampled_from([0.0, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sb_regions_never_mix_signs(k, c, p_pos, seed):
    rng = np.random.RandomState(seed)
    w = randn(rng, k, c, 3, 3)
    beta = ref.default_beta(k, p_pos)
    wq = np.asarray(ref.signed_binary_quantize_ref(w, beta, 0.05))
    for fi in range(k):
        f = wq[fi]
        assert not ((f > 0).any() and (f < 0).any()), f"filter {fi} mixes signs"


def test_ternary_sparser_than_binary():
    rng = np.random.RandomState(1)
    w = randn(rng, 8, 16, 3, 3)
    assert float(jnp.mean(ref.ternary_quantize_ref(w) == 0)) > 0.0
    assert float(jnp.mean(ref.binary_quantize_ref(w) == 0)) == 0.0


def test_ede_t_k_schedule():
    t0, k0 = ref.ede_t_k(jnp.float32(0.0))
    t1, k1 = ref.ede_t_k(jnp.float32(1.0))
    assert float(t0) == pytest.approx(0.1, rel=1e-5)
    assert float(t1) == pytest.approx(10.0, rel=1e-4)
    assert float(k0) == pytest.approx(10.0, rel=1e-5)
    assert float(k1) == pytest.approx(1.0, rel=1e-5)
