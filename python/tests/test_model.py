"""L2 model: shapes, init/apply agreement, training dynamics, and the
signatures the AOT manifest promises to the rust runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model

jax.config.update("jax_platform_name", "cpu")


def tiny_cfg(**kw):
    base = dict(name="t", depth=8, image_size=16, batch_size=4)
    base.update(kw)
    return common.ModelConfig(**base)


def test_init_is_deterministic():
    cfg = tiny_cfg()
    p1, bn1, c1, q1, _ = model.init(cfg, 0)
    p2, _, _, _, _ = model.init(cfg, 0)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
    assert sorted(p1) == sorted(p2)
    assert q1  # quantized convs exist


def test_seeds_differ():
    cfg = tiny_cfg()
    p1, *_ = model.init(cfg, 0)
    p2, *_ = model.init(cfg, 1)
    k = sorted(p1)[0]
    assert not np.array_equal(np.asarray(p1[k]), np.asarray(p2[k]))


@pytest.mark.parametrize("arch,depth,px", [
    ("cifar_resnet", 8, 16),
    ("cifar_resnet", 20, 32),
    ("resnet18", 20, 32),
    ("vgg_small", 8, 32),
    ("alexnet_small", 8, 32),
])
def test_forward_shapes(arch, depth, px):
    cfg = tiny_cfg(arch=arch, depth=depth, image_size=px, width_mult=0.25)
    params, bn, consts, _, conv_log = model.init(cfg, 0)
    x = jnp.zeros((4, 3, px, px))
    logits, new_bn = model.apply_model(cfg, params, bn, consts, x, True, jnp.float32(0.0))
    assert logits.shape == (4, 10)
    assert sorted(new_bn) == sorted(bn)
    assert conv_log  # geometry recorded for the manifest


def test_first_layer_not_quantized():
    cfg = tiny_cfg()
    _, _, _, qnames, conv_log = model.init(cfg, 0)
    assert conv_log[0]["quantized"] is False
    assert all(l["quantized"] for l in conv_log[1:])
    assert f"{conv_log[0]['name']}.w" not in qnames


def test_train_step_reduces_loss_all_schemes():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 3, 16, 16).astype(np.float32))
    y = jnp.asarray(np.arange(4) % 10)
    for scheme in ("fp", "binary", "ternary", "sb"):
        cfg = tiny_cfg(scheme=scheme)
        params, bn, consts, qnames, _ = model.init(cfg, 0)
        step = jax.jit(model.make_train_step(cfg, qnames))
        m = {k: jnp.zeros_like(v) for k, v in params.items()}
        v = {k: jnp.zeros_like(vv) for k, vv in params.items()}
        first = None
        for i in range(8):
            out = step(params, bn, consts, m, v, x, y,
                       jnp.float32(5e-3), jnp.float32(i + 1), jnp.float32(0.0))
            loss, _, params, bn, m, v = out
            if first is None:
                first = float(loss)
        assert float(loss) < first, f"{scheme}: {first} -> {float(loss)}"
        assert np.isfinite(float(loss))


def test_latent_weights_clamped():
    cfg = tiny_cfg(scheme="sb")
    params, bn, consts, qnames, _ = model.init(cfg, 0)
    step = jax.jit(model.make_train_step(cfg, qnames))
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4, 3, 16, 16).astype(np.float32))
    y = jnp.asarray(np.arange(4) % 10)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}
    for i in range(4):
        out = step(params, bn, consts, m, v, x, y,
                   jnp.float32(0.5), jnp.float32(i + 1), jnp.float32(0.0))
        _, _, params, bn, m, v = out
    for name in qnames:
        w = np.asarray(params[name])
        assert w.max() <= 1.0 + 1e-6 and w.min() >= -1.0 - 1e-6, name


def test_infer_eval_mode_uses_running_stats():
    cfg = tiny_cfg(scheme="sb")
    params, bn, consts, _, _ = model.init(cfg, 0)
    infer = jax.jit(model.make_infer(cfg, use_pallas=False))
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(4, 3, 16, 16).astype(np.float32))
    l1 = infer(params, bn, consts, x)
    l2 = infer(params, bn, consts, x)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert l1.shape == (4, 10)


def test_pallas_and_lax_infer_agree():
    """The Pallas sb hot path and the plain lax path compute the same logits."""
    cfg = tiny_cfg(scheme="sb")
    params, bn, consts, _, _ = model.init(cfg, 0)
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(4, 3, 16, 16).astype(np.float32))
    lp = model.make_infer(cfg, use_pallas=True)(params, bn, consts, x)
    ll = model.make_infer(cfg, use_pallas=False)(params, bn, consts, x)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ll), rtol=1e-3, atol=1e-3)


def test_param_counts_sb_sparser_than_binary():
    cb = tiny_cfg(scheme="binary")
    cs = tiny_cfg(scheme="sb")
    pb, _, cob, qb, _ = model.init(cb, 0)
    ps, _, cos, qs, _ = model.init(cs, 0)
    _, qtot_b, eff_b = model.param_counts(cb, pb, cob, qb)
    _, qtot_s, eff_s = model.param_counts(cs, ps, cos, qs)
    assert qtot_b == qtot_s
    assert eff_b == qtot_b          # binary dense
    assert eff_s < 0.7 * qtot_s     # sb sparse
