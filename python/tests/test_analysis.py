"""L1 structural-performance guardrails: the shipped Pallas tiling must
fit VMEM with full MXU utilization (the interpret=True path cannot
measure TPU time, so these assertions *are* the kernel perf contract)."""

from compile.kernels import analysis


def test_default_tiling_fits_vmem():
    t = analysis.default_tiling_report()
    assert t.fits, f"default tiling uses {t.vmem_bytes} bytes"
    assert t.vmem_fraction < 0.5


def test_default_tiling_saturates_mxu():
    t = analysis.default_tiling_report()
    assert t.mxu_utilization == 1.0, t


def test_default_intensity_near_structural_max():
    t = analysis.default_tiling_report()
    best = analysis.best_tiling()
    # paper-style efficiency ratio: achieved / structural roofline >= 0.5
    # (the residual gap is deliberate N-padding headroom — see the
    # DEFAULT_* comment in signed_binary.py)
    assert t.arithmetic_intensity >= 0.5 * best.arithmetic_intensity, (t, best)
    assert t.arithmetic_intensity >= 64.0


def test_vmem_scales_with_tiles():
    small = analysis.analyze_tiling(128, 128, 128)
    big = analysis.analyze_tiling(256, 256, 256)
    assert big.vmem_bytes > small.vmem_bytes
    assert big.arithmetic_intensity > small.arithmetic_intensity


def test_misaligned_tiles_lose_mxu_utilization():
    t = analysis.analyze_tiling(100, 128, 128)
    assert t.mxu_utilization < 1.0


def test_conv_mapping_resnet_block():
    rep = analysis.analyze_conv_as_gemm(
        n=8, c=256, h=16, w=16, k=256, r=3, s=3, bm=128, bn=128, bk=128
    )
    assert rep["grid"][0] >= 1 and rep["grid"][2] >= 1
    assert 0.0 <= rep["pad_waste"] < 0.5
    assert rep["tile"].fits
